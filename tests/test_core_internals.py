"""Unit tests for the core math internals (Lagrangians, inner rollouts,
stationarity algebra)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_quadratic_problem
from repro.core import afto as afto_lib
from repro.core import cuts as cuts_lib
from repro.core import inner as inner_lib
from repro.core import lagrangian as lag
from repro.core.types import Hyper, InnerState2, InnerState3


@pytest.fixture(scope="module")
def setup():
    prob = make_quadratic_problem()
    hyper = Hyper(n_workers=4, s_active=3, tau=5, k_inner=4, p_max=4,
                  t_pre=5, t1=100, eta_x=0.05, eta_z=0.05, d1=3)
    state = afto_lib.init_state(prob, hyper)
    return prob, hyper, state


def test_l_p3_consensus_penalty(setup):
    """L_p3 grows quadratically with the consensus violation."""
    prob, hyper, state = setup
    st0 = state.inner3
    base = lag.l_p3(prob, hyper, state.z1, state.z2, st0)
    shifted = InnerState3(
        x3=jax.tree.map(lambda x: x + 1.0, st0.x3),
        z3=st0.z3, phi=st0.phi)
    moved = lag.l_p3(prob, hyper, state.z1, state.z2, shifted)
    # kappa3/2 * N * ||1||^2 = 0.5*0.5*4*3 = 3 extra penalty, plus f3 shift
    assert float(moved) > float(base)


def test_rollout3_reduces_inner_objective(setup):
    """K rounds of Eq. 5-7 should reduce the level-3 Lagrangian."""
    prob, hyper, state = setup
    st0 = InnerState3(
        x3=jax.tree.map(lambda x: x + 1.0, state.inner3.x3),
        z3=state.inner3.z3, phi=state.inner3.phi)
    before = lag.l_p3(prob, hyper, state.z1, state.z2, st0)
    stK = inner_lib.rollout3(prob, hyper, state.z1, state.z2, st0)
    after = lag.l_p3(prob, hyper, state.z1, state.z2,
                     InnerState3(x3=stK.x3, z3=stK.z3, phi=st0.phi))
    assert float(after) < float(before)


def test_h_i_zero_at_rollout_fixpoint(setup):
    """h_I(v) evaluated AT the rollout output is ~0 by construction."""
    prob, hyper, state = setup
    est = inner_lib.rollout3(prob, hyper, state.z1, state.z2,
                             state.inner3)
    h = inner_lib.h_i(prob, hyper, est.x3, est.z3, state.z1, state.z2,
                      state.inner3)
    assert float(h) < 1e-8


def test_h_i_gradients_flow_to_z(setup):
    """The mu-cut coefficients need dh/dz1, dh/dz2 through the rollout
    (second-order); they must be nonzero for a coupled problem."""
    prob, hyper, state = setup
    X3 = jax.tree.map(lambda x: x + 0.5, state.X3)
    g = jax.grad(
        lambda z1, z2: inner_lib.h_i(prob, hyper, X3, state.z3, z1, z2,
                                     state.inner3),
        argnums=(0, 1))(jnp.ones(3) * 0.3, state.z2)
    assert float(jnp.sum(jnp.abs(g[0]))) > 0.0


def test_l_p_hat_regularization_decreases(setup):
    """c1/c2 decay as (t+1)^{-1/4} down to the floor."""
    prob, hyper, state = setup
    c_early = float(hyper.c1(0))
    c_late = float(hyper.c1(10_000))
    assert c_early > c_late >= hyper.c1_floor


def test_afto_step_inactive_workers_frozen(setup):
    prob, hyper, state = setup
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    new = afto_lib.afto_step(prob, hyper, state, mask)
    for a, b in zip(jax.tree.leaves(state.X1), jax.tree.leaves(new.X1)):
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
        np.testing.assert_array_equal(np.asarray(a[3]), np.asarray(b[3]))


def test_cut_refresh_adds_both_layers(setup):
    prob, hyper, state = setup
    new = afto_lib.cut_refresh(prob, hyper, state)
    assert float(cuts_lib.n_active(new.cuts_i)) >= 1
    assert float(cuts_lib.n_active(new.cuts_ii)) >= 1
    # cut offsets are finite
    assert np.isfinite(np.asarray(new.cuts_i.c)).all()


def test_lambda_projection_bounds(setup):
    """lambda must stay in [0, sqrt(alpha4)] through ascent steps."""
    prob, hyper, state = setup
    state = afto_lib.cut_refresh(prob, hyper, state)
    mask = jnp.ones(4)
    for _ in range(5):
        state = afto_lib.afto_step(prob, hyper, state, mask)
    lam = np.asarray(state.lam)
    assert (lam >= 0).all() and (lam <= np.sqrt(hyper.alpha4) + 1e-6).all()
