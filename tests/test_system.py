"""End-to-end behaviour of the paper's system (Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_hyper, make_quadratic_problem
from repro.core import (Hyper, StragglerConfig, run, stationarity_gap_sq)


def _hyper(n=4, **kw):
    # conftest's shared builder, with this file's historical n= alias
    return make_hyper(n_workers=n, **kw)


def test_afto_reduces_stationarity_gap():
    prob = make_quadratic_problem()
    hyper = _hyper()
    res = run(prob, hyper, n_iterations=200, metrics_every=25)
    gaps = res.history["gap_sq"]
    # dual warm-up can bump the gap early; require clear net decrease
    assert gaps[-1] < gaps[0] * 0.9, gaps
    assert gaps[-1] < max(gaps) * 0.8, gaps
    assert all(np.isfinite(gaps))


def test_afto_builds_and_maintains_cuts():
    prob = make_quadratic_problem()
    res = run(prob, _hyper(), n_iterations=30, metrics_every=10)
    assert res.history["n_cuts_i"][-1] >= 1
    assert res.history["n_cuts_ii"][-1] >= 1


def test_staleness_respects_tau():
    prob = make_quadratic_problem()
    hyper = _hyper(tau=4)
    cfg = StragglerConfig(n_workers=4, s_active=2, tau=4, n_stragglers=2,
                          straggler_slowdown=20.0, seed=3)
    res = run(prob, hyper, scheduler_cfg=cfg, n_iterations=60,
              metrics_every=5)
    assert max(res.history["max_staleness"]) <= 4


def test_sfto_equals_afto_when_s_equals_n():
    """S=N (synchronous) must activate every worker each iteration."""
    prob = make_quadratic_problem()
    hyper = _hyper(s_active=4)
    cfg = StragglerConfig(n_workers=4, s_active=4, tau=100,
                          n_stragglers=1, seed=0)
    res = run(prob, hyper, scheduler_cfg=cfg, n_iterations=20,
              metrics_every=5)
    assert max(res.history["max_staleness"]) <= 1


def test_consensus_violation_bounded():
    prob = make_quadratic_problem()
    hyper = _hyper()
    from repro.core import afto as afto_lib
    from repro.core.scheduler import StragglerScheduler

    state = afto_lib.init_state(prob, hyper)
    sched = StragglerScheduler(StragglerConfig(
        n_workers=4, s_active=3, tau=5, seed=0))
    step = jax.jit(lambda s, m: afto_lib.afto_step(prob, hyper, s, m))

    def viol(st):
        return float(sum(jnp.sum((st.X1[j] - st.z1) ** 2)
                         for j in range(4)))

    v0 = None
    for it in range(120):
        mask, _ = sched.next_active()
        state = step(state, jnp.asarray(mask))
        if it == 20:
            v0 = viol(state)
    assert viol(state) <= v0 * 1.5 + 1e-3  # bounded, typically shrinking
