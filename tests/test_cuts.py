"""mu-cut construction, polytope maintenance, Lagrangian algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cuts as cuts_lib
from repro.core.weakly_convex import estimate_mu, first_order_gap
from repro.utils.tree import tree_dot


def _tpl(d=3):
    return jnp.zeros((d,))


def test_empty_cutset_inactive():
    cs = cuts_lib.empty_cutset(4, 2, _tpl(), _tpl(), _tpl())
    val = cuts_lib.eval_cuts(cs, jnp.ones(3), jnp.ones(3), jnp.ones(3))
    np.testing.assert_array_equal(np.asarray(val), np.zeros(4))


def test_add_eval_drop_roundtrip():
    cs = cuts_lib.empty_cutset(3, 2, _tpl(), _tpl(), _tpl())
    coeffs = {"a1": jnp.array([1.0, 0, 0]), "a2": jnp.zeros(3),
              "a3": jnp.zeros(3)}
    cs = cuts_lib.add_cut(cs, coeffs, 0.5, t=0)
    assert float(cuts_lib.n_active(cs)) == 1
    z1 = jnp.array([2.0, 0, 0])
    val = cuts_lib.eval_cuts(cs, z1, jnp.zeros(3), jnp.zeros(3))
    # <a1,z1> - c = 2 - 0.5
    np.testing.assert_allclose(np.asarray(val)[np.argmax(np.abs(val))],
                               1.5, rtol=1e-6)
    cs = cuts_lib.drop_inactive(cs, jnp.zeros(3))
    assert float(cuts_lib.n_active(cs)) == 0


def test_add_evicts_oldest_when_full():
    cs = cuts_lib.empty_cutset(2, 1, _tpl(1), _tpl(1), _tpl(1))
    for t in range(3):
        coeffs = {"a1": jnp.array([float(t + 1)])}
        cs = cuts_lib.add_cut(cs, coeffs, 0.0, t=t)
    ages = np.asarray(cs.age)
    assert set(ages.tolist()) == {1, 2}       # slot with age 0 evicted


def test_mu_cut_validity_on_weakly_convex_fn():
    """The linearization c-bound must contain every feasible point
    (Prop. 3.3): for h mu-weakly convex and any point with h(v) <= eps,
    <g, v> <= c must hold."""
    # h(v) = ||v||^2 - 0.25||v||^2 via cos perturbation: curvature >= -mu
    def h(v):
        return jnp.sum(v ** 2) + 0.5 * jnp.sum(jnp.cos(2.0 * v))

    mu = 2.0 * 0.5 * 2.0  # |d2/dv2 of 0.5*cos(2v)| <= 2
    key = jax.random.PRNGKey(0)
    alpha = 4.0   # bound ||v||^2 <= alpha
    eps = float(h(jnp.zeros(3))) + 0.3

    v0 = jax.random.normal(key, (3,)) * 0.5
    g = jax.grad(h)(v0)
    c = eps + mu * (alpha + float(jnp.sum(v0 ** 2))) - float(h(v0)) \
        + float(g @ v0)

    # sample feasible points within the alpha-ball; none may violate
    for i in range(200):
        v = jax.random.normal(jax.random.fold_in(key, i), (3,))
        v = v / jnp.maximum(1.0, jnp.linalg.norm(v) / 2.0)  # ||v||<=2
        if float(h(v)) <= eps:
            assert float(g @ v) <= c + 1e-4


def test_first_order_gap_nonneg_for_quadratic():
    fn = lambda x: jnp.sum(x ** 2) - 0.3 * jnp.sum(x) ** 2
    # hessian 2I - 0.6 * 11^T: min eig = 2 - 0.6*d for d=3 -> -mu = 0.2-2
    mu = 2.0
    key = jax.random.PRNGKey(1)
    for i in range(50):
        x = jax.random.normal(jax.random.fold_in(key, i), (3,))
        xr = jax.random.normal(jax.random.fold_in(key, 1000 + i), (3,))
        assert float(first_order_gap(fn, x, xr, mu)) >= -1e-5


def test_estimate_mu_convex_is_zero():
    fn = lambda x: jnp.sum(x ** 2)
    mu = estimate_mu(fn, jnp.zeros(4), jax.random.PRNGKey(0))
    assert float(mu) <= 1e-6


def test_estimate_mu_detects_concavity():
    fn = lambda x: -jnp.sum(x ** 2)
    mu = estimate_mu(fn, jnp.zeros(4), jax.random.PRNGKey(0))
    assert abs(float(mu) - 2.0) < 0.2


def test_cut_weighted_coeff_matches_manual():
    cs = cuts_lib.empty_cutset(3, 2, _tpl(), _tpl(), _tpl())
    cs = cuts_lib.add_cut(cs, {"a1": jnp.array([1.0, 2, 3])}, 0.0, 0)
    cs = cuts_lib.add_cut(cs, {"a1": jnp.array([0.0, 1, 0])}, 0.0, 1)
    w = jnp.array([0.5, 2.0, 7.0])
    got = cuts_lib.cut_weighted_coeff(cs, w, "a1")
    want = 0.5 * jnp.array([1.0, 2, 3]) + 2.0 * jnp.array([0.0, 1, 0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)
