"""mu-cut construction, canonical flat polytope maintenance, Lagrangian
algebra, and the to_tree/from_tree compatibility boundary."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cuts as cuts_lib
from repro.core.types import FlatCuts
from repro.core.weakly_convex import estimate_mu, first_order_gap
from repro.utils.tree import tree_dot


def _tpl(d=3):
    return jnp.zeros((d,))


def test_empty_cuts_inactive():
    cs = cuts_lib.empty_cuts(4, 2, _tpl(), _tpl(), _tpl())
    assert isinstance(cs, FlatCuts)
    assert cs.a.shape == (4, cs.spec.d_total)
    val = cuts_lib.eval_cuts(cs, jnp.ones(3), jnp.ones(3), jnp.ones(3))
    np.testing.assert_array_equal(np.asarray(val), np.zeros(4))


def test_add_eval_drop_roundtrip():
    cs = cuts_lib.empty_cuts(3, 2, _tpl(), _tpl(), _tpl())
    coeffs = {"a1": jnp.array([1.0, 0, 0]), "a2": jnp.zeros(3),
              "a3": jnp.zeros(3)}
    cs = cuts_lib.add_cut(cs, coeffs, 0.5, t=0)
    assert float(cuts_lib.n_active(cs)) == 1
    z1 = jnp.array([2.0, 0, 0])
    val = cuts_lib.eval_cuts(cs, z1, jnp.zeros(3), jnp.zeros(3))
    # <a1,z1> - c = 2 - 0.5
    np.testing.assert_allclose(np.asarray(val)[np.argmax(np.abs(val))],
                               1.5, rtol=1e-6)
    cs = cuts_lib.drop_inactive(cs, jnp.zeros(3))
    assert float(cuts_lib.n_active(cs)) == 0


def test_add_evicts_oldest_when_full():
    cs = cuts_lib.empty_cuts(2, 1, _tpl(1), _tpl(1), _tpl(1))
    for t in range(3):
        coeffs = {"a1": jnp.array([float(t + 1)])}
        cs = cuts_lib.add_cut(cs, coeffs, 0.0, t=t)
    ages = np.asarray(cs.age)
    assert set(ages.tolist()) == {1, 2}       # slot with age 0 evicted


def test_add_cut_is_jit_row_write():
    """add_cut on the canonical layout stays shape-stable under jit."""
    cs = cuts_lib.empty_cuts(3, 2, _tpl(), _tpl(), _tpl())

    @jax.jit
    def add(cs, a1, c, t):
        return cuts_lib.add_cut(cs, {"a1": a1}, c, t)

    for t in range(5):
        cs = add(cs, jnp.full((3,), float(t)), 0.1 * t, t)
    assert cs.a.shape == (3, cs.spec.d_total)
    assert float(cuts_lib.n_active(cs)) == 3


def test_mu_cut_validity_on_weakly_convex_fn():
    """The linearization c-bound must contain every feasible point
    (Prop. 3.3): for h mu-weakly convex and any point with h(v) <= eps,
    <g, v> <= c must hold."""
    # h(v) = ||v||^2 - 0.25||v||^2 via cos perturbation: curvature >= -mu
    def h(v):
        return jnp.sum(v ** 2) + 0.5 * jnp.sum(jnp.cos(2.0 * v))

    mu = 2.0 * 0.5 * 2.0  # |d2/dv2 of 0.5*cos(2v)| <= 2
    key = jax.random.PRNGKey(0)
    alpha = 4.0   # bound ||v||^2 <= alpha
    eps = float(h(jnp.zeros(3))) + 0.3

    v0 = jax.random.normal(key, (3,)) * 0.5
    g = jax.grad(h)(v0)
    c = eps + mu * (alpha + float(jnp.sum(v0 ** 2))) - float(h(v0)) \
        + float(g @ v0)

    # sample feasible points within the alpha-ball; none may violate
    for i in range(200):
        v = jax.random.normal(jax.random.fold_in(key, i), (3,))
        v = v / jnp.maximum(1.0, jnp.linalg.norm(v) / 2.0)  # ||v||<=2
        if float(h(v)) <= eps:
            assert float(g @ v) <= c + 1e-4


def test_first_order_gap_nonneg_for_quadratic():
    fn = lambda x: jnp.sum(x ** 2) - 0.3 * jnp.sum(x) ** 2
    # hessian 2I - 0.6 * 11^T: min eig = 2 - 0.6*d for d=3 -> -mu = 0.2-2
    mu = 2.0
    key = jax.random.PRNGKey(1)
    for i in range(50):
        x = jax.random.normal(jax.random.fold_in(key, i), (3,))
        xr = jax.random.normal(jax.random.fold_in(key, 1000 + i), (3,))
        assert float(first_order_gap(fn, x, xr, mu)) >= -1e-5


def test_estimate_mu_convex_is_zero():
    fn = lambda x: jnp.sum(x ** 2)
    mu = estimate_mu(fn, jnp.zeros(4), jax.random.PRNGKey(0))
    assert float(mu) <= 1e-6


def test_estimate_mu_detects_concavity():
    fn = lambda x: -jnp.sum(x ** 2)
    mu = estimate_mu(fn, jnp.zeros(4), jax.random.PRNGKey(0))
    assert abs(float(mu) - 2.0) < 0.2


def test_cut_weighted_coeff_matches_manual():
    cs = cuts_lib.empty_cuts(3, 2, _tpl(), _tpl(), _tpl())
    cs = cuts_lib.add_cut(cs, {"a1": jnp.array([1.0, 2, 3])}, 0.0, 0)
    cs = cuts_lib.add_cut(cs, {"a1": jnp.array([0.0, 1, 0])}, 0.0, 1)
    w = jnp.array([0.5, 2.0, 7.0])
    got = cuts_lib.cut_weighted_coeff(cs, w, "a1")
    want = 0.5 * jnp.array([1.0, 2, 3]) + 2.0 * jnp.array([0.0, 1, 0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)
    # the tree-view path agrees
    got_tree = cuts_lib.cut_weighted_coeff(cuts_lib.to_tree(cs), w, "a1")
    np.testing.assert_allclose(np.asarray(got_tree), np.asarray(want),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# canonical (P, D) layout: round-trips + flat-vs-tree-vs-kernel regression
# ---------------------------------------------------------------------------

def _rand_tree(tpl, k, lead=()):
    leaves, tdef = jax.tree.flatten(tpl)
    outs = [jax.random.normal(jax.random.fold_in(k, i), lead + l.shape)
            for i, l in enumerate(leaves)]
    return jax.tree.unflatten(tdef, outs)


def _nested_cuts(p_max=4, n_workers=2, key=None):
    """A FlatCuts over nested/mixed-shape templates with two random cuts."""
    key = jax.random.PRNGKey(0) if key is None else key
    z1_tpl = {"phi": jnp.zeros((2,))}
    z2_tpl = {"w": jnp.zeros((2, 3)), "b": jnp.zeros((3,))}
    z3_tpl = jnp.zeros((4,))
    cs = cuts_lib.empty_cuts(p_max, n_workers, z1_tpl, z2_tpl, z3_tpl)

    for t in range(2):
        k = jax.random.fold_in(key, t)
        coeffs = {"a1": _rand_tree(z1_tpl, k),
                  "a2": _rand_tree(z2_tpl, jax.random.fold_in(k, 10)),
                  "a3": _rand_tree(z3_tpl, jax.random.fold_in(k, 20)),
                  "b2": _rand_tree(z2_tpl, jax.random.fold_in(k, 30),
                                   (n_workers,)),
                  "b3": _rand_tree(z3_tpl, jax.random.fold_in(k, 40),
                                   (n_workers,))}
        cs = cuts_lib.add_cut(cs, coeffs, 0.1 * t, t)
    return cs, (z1_tpl, z2_tpl, z3_tpl)


def test_to_tree_from_tree_roundtrip_nested():
    """to_tree materializes the block view; from_tree reproduces the
    canonical matrix bit-identically (f32 templates)."""
    fc, _ = _nested_cuts()
    tree = cuts_lib.to_tree(fc)
    back = cuts_lib.from_tree(tree)
    np.testing.assert_array_equal(np.asarray(back.a), np.asarray(fc.a))
    np.testing.assert_array_equal(np.asarray(back.c), np.asarray(fc.c))
    np.testing.assert_array_equal(np.asarray(back.active),
                                  np.asarray(fc.active))
    assert back.spec == fc.spec
    # per-slot rows unflatten to the block-view slots
    for slot in range(2):
        blocks = cuts_lib.unflatten_coeff(fc.spec, fc.a[slot])
        for got, want in zip(
                jax.tree.leaves(blocks),
                jax.tree.leaves(tuple(
                    jax.tree.map(lambda x: x[slot], getattr(tree, n))
                    for n in ("a1", "a2", "a3", "b2", "b3")))):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-6)


def test_flatten_point_matches_kernel_ref():
    """canonical eval == kernels/ref.py:cut_eval_ref on the stored
    operands == the tree-op eval_cuts_tree reference."""
    from repro.kernels import ref as kref

    fc, (z1_tpl, z2_tpl, z3_tpl) = _nested_cuts()
    spec = fc.spec
    key = jax.random.PRNGKey(7)
    z1 = jax.tree.map(lambda x: jax.random.normal(key, x.shape), z1_tpl)
    z2 = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, 1), x.shape),
        z2_tpl)
    z3 = jax.random.normal(jax.random.fold_in(key, 2), (4,))
    X2 = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, 3),
                                    (2,) + x.shape), z2_tpl)
    X3 = jax.random.normal(jax.random.fold_in(key, 4), (2, 4))

    v = cuts_lib.flatten_point(spec, z1, z2, z3, X2, X3)
    want_tree = cuts_lib.eval_cuts_tree(fc, z1, z2, z3, X2=X2, X3=X3)
    want_ref = kref.cut_eval_ref(fc.a, v, fc.c, fc.active)
    np.testing.assert_allclose(np.asarray(want_ref), np.asarray(want_tree),
                               rtol=1e-5, atol=1e-6)
    got = cuts_lib.eval_cuts(fc, z1, z2, z3, X2=X2, X3=X3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_ref),
                               rtol=1e-5, atol=1e-6)
    # the block-tree compatibility view evaluates identically
    got_view = cuts_lib.eval_cuts(cuts_lib.to_tree(fc), z1, z2, z3,
                                  X2=X2, X3=X3)
    np.testing.assert_allclose(np.asarray(got_view), np.asarray(want_ref),
                               rtol=1e-5, atol=1e-6)
    # the Pallas kernel route agrees too (interpret off-TPU)
    got_k = cuts_lib.eval_cuts_flat(fc.a, v, fc.c, fc.active,
                                    impl="pallas")
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want_ref),
                               rtol=1e-5, atol=1e-6)
    # X2=None zeroes the b2 columns
    np.testing.assert_allclose(
        np.asarray(cuts_lib.eval_cuts(fc, z1, z2, z3, X3=X3)),
        np.asarray(cuts_lib.eval_cuts_tree(fc, z1, z2, z3, X3=X3)),
        rtol=1e-5, atol=1e-6)


def test_cut_weighted_coeff_flat_matches_tree_ops():
    fc, _ = _nested_cuts()
    tree = cuts_lib.to_tree(fc)
    w = jnp.array([0.5, -2.0, 7.0, 0.25]) * fc.active
    flat = cuts_lib.cut_weighted_coeff_flat(fc.spec, fc.a, w)
    for b_idx, name in enumerate(("a1", "a2", "a3", "b2", "b3")):
        want = cuts_lib.cut_weighted_coeff(tree, w, name)
        got_blk = cuts_lib.cut_weighted_coeff(fc, w, name)
        for g, gb, t in zip(jax.tree.leaves(flat[b_idx]),
                            jax.tree.leaves(got_blk),
                            jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(t),
                                       rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(gb), np.asarray(t),
                                       rtol=1e-5, atol=1e-6)


def test_cut_coeff_per_worker_matches_tree_einsum():
    """The Eq. 16 per-worker stale-weight contraction off the canonical
    matrix equals the block-tree einsum."""
    fc, _ = _nested_cuts()
    tree = cuts_lib.to_tree(fc)
    n_workers = 2
    lam_np = jax.random.normal(jax.random.PRNGKey(3), (n_workers, 4))
    for block in ("b2", "b3"):
        got = cuts_lib.cut_coeff_per_worker(fc, lam_np, block)
        w = lam_np * fc.active[None, :]
        want = jax.tree.map(
            lambda b: jnp.einsum("np,pn...->n...", w,
                                 b.astype(jnp.float32)).astype(b.dtype),
            getattr(tree, block))
        for g, t in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(t),
                                       rtol=1e-5, atol=1e-6)


def test_spec_is_cached_per_layout():
    fc, _ = _nested_cuts()
    fc2, _ = _nested_cuts()
    assert fc.spec is fc2.spec               # template cache
    assert cuts_lib.flat_spec(fc) is fc.spec
    other = cuts_lib.empty_cuts(2, 1, _tpl(1), _tpl(1), _tpl(1))
    assert other.spec is not fc.spec
    # the tree-view spec is value-equal (jit-static keys match)
    assert cuts_lib.flat_spec(cuts_lib.to_tree(fc)) == fc.spec


# ---------------------------------------------------------------------------
# worker-column sharding of the canonical operator
# ---------------------------------------------------------------------------

def test_shard_unshard_roundtrip_bit_identical():
    """The worker-column partition is exact: shard -> unshard reproduces
    the canonical matrix (and metadata) bitwise, and each shard's local
    spec carries n_loc workers on the b-block point shapes."""
    fc, _ = _nested_cuts(p_max=4, n_workers=4)
    for w in (1, 2, 4):
        sh = cuts_lib.shard_cuts(fc, w)
        assert sh.a.shape == (w, 4, sh.spec.d_total)
        na = cuts_lib.n_a_leaves(fc.spec)
        for i, shp in enumerate(sh.spec.shapes):
            if i >= na:
                assert shp[0] == 4 // w
        back = cuts_lib.unshard_cuts(sh, fc.spec)
        np.testing.assert_array_equal(np.asarray(back.a), np.asarray(fc.a))
        np.testing.assert_array_equal(np.asarray(back.c), np.asarray(fc.c))
        assert back.spec == fc.spec
    with pytest.raises(ValueError):
        cuts_lib.shard_cuts(fc, 3)


def _worker_split_eval_body(p_max, n_workers, n_shards, active_mask, seed):
    """Partitioning the (P, D) operator by worker columns and summing the
    per-shard `cut_eval` contributions reproduces the full-width
    contraction for arbitrary active-row masks.

    Each shard contributes its b-column mat-vec; shard 0 also carries the
    replicated a-columns and the -c offset.  The partition covers every
    column exactly once (bit-identical shard->unshard round trip above),
    so the summed contraction differs from the full-width one only by
    f32 re-association — asserted at tight tolerance.
    """
    key = jax.random.PRNGKey(seed)
    tpl = jnp.zeros((2,))
    fc = cuts_lib.empty_cuts(p_max, n_workers, tpl, tpl, tpl)
    for t in range(p_max):
        k = jax.random.fold_in(key, t)
        fc = cuts_lib.add_cut(fc, {
            "a1": jax.random.normal(k, (2,)),
            "a2": jax.random.normal(jax.random.fold_in(k, 1), (2,)),
            "a3": jax.random.normal(jax.random.fold_in(k, 2), (2,)),
            "b2": jax.random.normal(jax.random.fold_in(k, 3),
                                    (n_workers, 2)),
            "b3": jax.random.normal(jax.random.fold_in(k, 4),
                                    (n_workers, 2)),
        }, float(t) * 0.1, t)
    fc = cuts_lib.drop_inactive(fc, jnp.asarray(active_mask))

    kp = jax.random.fold_in(key, 999)
    z1 = jax.random.normal(kp, (2,))
    z2 = jax.random.normal(jax.random.fold_in(kp, 1), (2,))
    z3 = jax.random.normal(jax.random.fold_in(kp, 2), (2,))
    X2 = jax.random.normal(jax.random.fold_in(kp, 3), (n_workers, 2))
    X3 = jax.random.normal(jax.random.fold_in(kp, 4), (n_workers, 2))

    v = cuts_lib.flatten_point(fc.spec, z1, z2, z3, X2, X3)
    want = cuts_lib.eval_cuts_flat(fc.a, v, fc.c, fc.active, impl="ref")

    sh = cuts_lib.shard_cuts(fc, n_shards)
    da = cuts_lib.b_col_start(sh.spec)
    n_loc = n_workers // n_shards
    total = jnp.zeros((p_max,))
    for w in range(n_shards):
        X2w = X2[w * n_loc:(w + 1) * n_loc]
        X3w = X3[w * n_loc:(w + 1) * n_loc]
        vb = cuts_lib.flatten_point(sh.spec, None, None, None,
                                    X2w, X3w)[da:]
        total = total + (sh.a[w, :, da:] @ vb) * fc.active
        if w == 0:      # replicated a-columns + offset counted once
            va = cuts_lib.flatten_point(sh.spec, z1, z2, z3,
                                        None, None)[:da]
            total = total + cuts_lib.eval_cuts_flat(
                sh.a[w, :, :da], va, fc.c, fc.active, impl="ref")
    np.testing.assert_allclose(np.asarray(total), np.asarray(want),
                               rtol=2e-6, atol=1e-6)


def test_worker_split_eval_matches_full_width():
    _worker_split_eval_body(4, 4, 2, np.array([1, 0, 1, 1], np.float32),
                            seed=0)
    _worker_split_eval_body(3, 6, 3, np.array([0, 1, 1], np.float32),
                            seed=5)


# ---------------------------------------------------------------------------
# hypothesis: round-trips + incremental-maintenance drift guard
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def _cut_layouts(draw):
        """(p_max, n_workers, templates, active mask), random nesting."""
        p_max = draw(st.integers(1, 5))
        n_workers = draw(st.integers(1, 3))

        def tpl_strategy():
            leaf = st.tuples(st.integers(1, 3), st.integers(1, 3)).map(
                lambda s: jnp.zeros(s))
            return st.one_of(
                leaf,
                st.lists(leaf, min_size=1, max_size=2).map(tuple),
                st.dictionaries(st.sampled_from(("a", "b", "c")), leaf,
                                min_size=1, max_size=2))

        tpls = tuple(draw(tpl_strategy()) for _ in range(3))
        active = draw(st.lists(st.booleans(), min_size=p_max,
                               max_size=p_max))
        return p_max, n_workers, tpls, np.asarray(active, np.float32)

    @st.composite
    def _op_sequences(draw):
        """Interleaved add/drop op streams (adds > p_max force evictions)."""
        p_max = draw(st.integers(1, 4))
        ops = draw(st.lists(
            st.one_of(
                st.tuples(st.just("add"), st.integers(0, 2 ** 16)),
                st.tuples(st.just("drop"), st.integers(0, 2 ** 16))),
            min_size=1, max_size=3 * p_max + 4))
        return p_max, draw(st.integers(1, 3)), ops


def _roundtrip_property_body(layout, seed):
    """Canonical rows unflatten back to the to_tree coefficient blocks
    and flatten_point inverts unflatten_coeff, for arbitrary pytree
    templates, slot counts, worker counts and active masks."""
    p_max, n_workers, (z1_tpl, z2_tpl, z3_tpl), active = layout
    fc = cuts_lib.empty_cuts(p_max, n_workers, z1_tpl, z2_tpl, z3_tpl)
    key = jax.random.PRNGKey(seed)

    for t in range(p_max):
        k = jax.random.fold_in(key, t)
        fc = cuts_lib.add_cut(fc, {
            "a1": _rand_tree(z1_tpl, k), "a2": _rand_tree(z2_tpl, k),
            "a3": _rand_tree(z3_tpl, k),
            "b2": _rand_tree(z2_tpl, jax.random.fold_in(k, 1),
                             (n_workers,)),
            "b3": _rand_tree(z3_tpl, jax.random.fold_in(k, 2),
                             (n_workers,)),
        }, float(t), t)
    fc = cuts_lib.drop_inactive(fc, jnp.asarray(active))

    spec = fc.spec
    assert fc.a.shape == (p_max, spec.d_total)
    tree = cuts_lib.to_tree(fc)
    slot = p_max - 1
    blocks = cuts_lib.unflatten_coeff(spec, fc.a[slot])
    for got, want in zip(
            jax.tree.leaves(blocks),
            jax.tree.leaves(tuple(
                jax.tree.map(lambda x: x[slot], getattr(tree, n))
                for n in ("a1", "a2", "a3", "b2", "b3")))):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=0)
    # flatten_point(unflatten_coeff(v)) == v
    v = jax.random.normal(key, (spec.d_total,))
    a1, a2, a3, b2, b3 = cuts_lib.unflatten_coeff(spec, v)
    v_back = cuts_lib.flatten_point(spec, a1, a2, a3, b2, b3)
    np.testing.assert_allclose(np.asarray(v_back), np.asarray(v),
                               rtol=1e-6, atol=0)
    # eval through the canonical path == tree-op reference at a random point
    val_flat = cuts_lib.eval_cuts(fc, a1, a2, a3, X2=b2, X3=b3)
    val_tree = cuts_lib.eval_cuts_tree(fc, a1, a2, a3, X2=b2, X3=b3)
    np.testing.assert_allclose(np.asarray(val_flat), np.asarray(val_tree),
                               rtol=1e-4, atol=1e-5)


def _maintenance_drift_body(ops_case, seed):
    """Incremental-maintenance drift guard: ANY interleaved sequence of
    add_cut / drop_inactive / evictions keeps the canonical matrix
    bit-identical to (a) re-flattening the to_tree view and (b) the same
    sequence applied to a legacy block-tree CutSet."""
    p_max, n_workers, ops = ops_case
    tpl = jnp.zeros((2, 2))
    fc = cuts_lib.empty_cuts(p_max, n_workers, tpl, tpl, tpl)
    cs = cuts_lib.empty_cutset(p_max, n_workers, tpl, tpl, tpl)
    key = jax.random.PRNGKey(seed)

    for t, (op, salt) in enumerate(ops):
        k = jax.random.fold_in(key, salt + 7919 * t)
        if op == "add":
            coeffs = {"a1": _rand_tree(tpl, k),
                      "a2": _rand_tree(tpl, jax.random.fold_in(k, 1)),
                      "a3": _rand_tree(tpl, jax.random.fold_in(k, 2)),
                      "b2": _rand_tree(tpl, jax.random.fold_in(k, 3),
                                       (n_workers,)),
                      "b3": _rand_tree(tpl, jax.random.fold_in(k, 4),
                                       (n_workers,))}
            c = float(jax.random.normal(jax.random.fold_in(k, 5), ()))
            fc = cuts_lib.add_cut(fc, coeffs, c, t)
            cs = cuts_lib.add_cut(cs, coeffs, c, t)
        else:
            mult = jax.random.bernoulli(k, 0.5, (p_max,)).astype(
                jnp.float32)
            fc = cuts_lib.drop_inactive(fc, mult)
            cs = cuts_lib.drop_inactive(cs, mult)

    # (a) re-flattening the to_tree view reproduces the matrix bitwise
    view = cuts_lib.to_tree(fc)
    np.testing.assert_array_equal(
        np.asarray(fc.a), np.asarray(cuts_lib.flatten_cuts(view)))
    # (b) the legacy tree path, maintained independently, agrees bitwise
    np.testing.assert_array_equal(np.asarray(fc.a),
                                  np.asarray(cuts_lib.flatten_cuts(cs)))
    for name in ("c", "active", "age"):
        np.testing.assert_array_equal(np.asarray(getattr(fc, name)),
                                      np.asarray(getattr(cs, name)))
    for g, w in zip(jax.tree.leaves(view), jax.tree.leaves(cs)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(_cut_layouts(), st.integers(0, 2 ** 31 - 1))
    def test_flatten_roundtrip_property(layout, seed):
        _roundtrip_property_body(layout, seed)

    @settings(max_examples=25, deadline=None)
    @given(_op_sequences(), st.integers(0, 2 ** 31 - 1))
    def test_incremental_maintenance_no_drift(ops_case, seed):
        _maintenance_drift_body(ops_case, seed)

    @settings(max_examples=25, deadline=None)
    @given(p_max=st.integers(1, 5), n_loc=st.integers(1, 3),
           n_shards=st.sampled_from((1, 2, 3)),
           active_bits=st.lists(st.booleans(), min_size=5, max_size=5),
           seed=st.integers(0, 2 ** 31 - 1))
    def test_worker_column_partition_property(p_max, n_loc, n_shards,
                                              active_bits, seed):
        """Arbitrary (P, workers, shards, active masks): per-shard
        `cut_eval` contributions over the worker-column partition sum to
        the full-width contraction (and shard->unshard is exact)."""
        _worker_split_eval_body(
            p_max, n_loc * n_shards, n_shards,
            np.asarray(active_bits[:p_max], np.float32), seed)
else:                                      # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_flatten_roundtrip_property():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_incremental_maintenance_no_drift():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_worker_column_partition_property():
        pass
