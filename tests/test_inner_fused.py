"""Fused inner-ADMM round (`hyper.use_fused_inner`) vs the scan-of-jnp
oracle: values, first gradients, and the h_II grad-of-grad must agree —
plus the fused op itself in pallas-interpret mode vs its jnp
decomposition."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cuts as cuts_lib
from repro.core import inner
from repro.core.types import (CutSet, Hyper, InnerState2, TrilevelProblem)
from repro.kernels import ops


def _toy(seed=0, n=3, p=5, d2=7, d1=4):
    """A small trilevel problem + a partially-active layer-I polytope."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 12)

    def f1(dj, x1, x2, x3):
        return jnp.sum(x1 ** 2)

    def f2(dj, z1, x2, x3):
        return jnp.sum((x2 - dj) ** 2) \
            + jnp.sum(z1) * jnp.sum(x2) + 0.1 * jnp.sum(x3) * jnp.sum(x2)

    def f3(dj, z1, z2, x3):
        return jnp.sum((x3 - z2[:d1]) ** 2)

    data = jax.random.normal(ks[0], (n, d2))
    prob = TrilevelProblem(f1=f1, f2=f2, f3=f3, data=data, n_workers=n,
                           x1_init=None, x2_init=None, x3_init=None)
    z1 = jax.random.normal(ks[1], (d1,))
    z2 = jax.random.normal(ks[2], (d2,))
    z3 = jax.random.normal(ks[3], (d1,))
    X3 = jax.random.normal(ks[4], (n, d1))
    X2 = jax.random.normal(ks[5], (n, d2))
    phi = jax.random.normal(ks[6], (n, d2)) * 0.1
    s = jnp.abs(jax.random.normal(ks[7], (p,)))
    gamma = jnp.abs(jax.random.normal(ks[8], (p,)))
    cs = CutSet(a1=jax.random.normal(ks[9], (p, d1)) * 0.1,
                a2=jax.random.normal(ks[10], (p, d2)) * 0.1,
                a3=jax.random.normal(ks[11], (p, d1)) * 0.1,
                b2=jnp.zeros((p, n, d2)),
                b3=jax.random.normal(ks[0], (p, n, d1)) * 0.1,
                c=jnp.linspace(-1.0, 1.0, p),
                active=jnp.array([1.0, 1.0, 0.0, 1.0, 1.0]),
                age=jnp.zeros((p,)))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        fc = cuts_lib.from_tree(cs)
    init = InnerState2(x2=X2, z2=z2, phi=phi, s=s, gamma=gamma)
    return prob, fc, init, z1, z2, z3, X2, X3


HYP_REF = Hyper(n_workers=3, k_inner=4)
HYP_FUSED = dataclasses.replace(HYP_REF, use_fused_inner=True)


def test_rollout2_fused_matches_oracle():
    """Final inner state through the fused round == the oracle scan body
    (bitwise off-TPU: the fused op auto-routes to the identical-math jnp
    decomposition there)."""
    prob, fc, init, z1, _z2, z3, _X2, X3 = _toy()
    ref = inner.rollout2(prob, HYP_REF, z1, z3, X3, fc, init)
    fus = inner.rollout2(prob, HYP_FUSED, z1, z3, X3, fc, init)
    for name in ("x2", "z2", "phi", "s", "gamma"):
        for a, b in zip(jax.tree.leaves(getattr(ref, name)),
                        jax.tree.leaves(getattr(fus, name))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=name)


def test_h_ii_grads_match_through_fused_round():
    """First gradients of h_II w.r.t. every outer variable flow through
    the fused round identically to the oracle."""
    prob, fc, init, z1, z2, z3, X2, X3 = _toy(seed=1)

    def h(hyp, z1, z3, X3):
        return inner.h_ii(prob, hyp, X2, z2, z1, z3, X3, fc, init)

    g_ref = jax.grad(h, argnums=(1, 2, 3))(HYP_REF, z1, z3, X3)
    g_fus = jax.grad(h, argnums=(1, 2, 3))(HYP_FUSED, z1, z3, X3)
    for name, a, b in zip(("z1", "z3", "X3"), g_ref, g_fus):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6, err_msg=name)


def test_h_ii_grad_of_grad_through_fused_round():
    """The cut-refresh shape: grad of ||grad h_II||^2 (second order
    through the K-round rollout and the fused op's custom JVP)."""
    prob, fc, init, z1, z2, z3, X2, X3 = _toy(seed=2)

    def h(hyp, z1, z3, X3):
        return inner.h_ii(prob, hyp, X2, z2, z1, z3, X3, fc, init)

    def gsum(hyp, z1):
        return jnp.sum(jax.grad(h, argnums=1)(hyp, z1, z3, X3) ** 2)

    gg_ref = jax.grad(gsum, argnums=1)(HYP_REF, z1)
    gg_fus = jax.grad(gsum, argnums=1)(HYP_FUSED, z1)
    assert float(jnp.max(jnp.abs(gg_ref))) > 0.0   # a real second order
    np.testing.assert_allclose(np.asarray(gg_ref), np.asarray(gg_fus),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("p,d", [(5, 300), (8, 4096)])
def test_fused_op_pallas_interpret_matches_ref(p, d):
    """The two-pass Pallas round kernel (interpret mode off-TPU) vs the
    jnp decomposition, forward and first gradients."""
    ks = jax.random.split(jax.random.PRNGKey(p + d), 8)
    a = jax.random.normal(ks[0], (p, d)) * (d ** -0.5)
    v = jax.random.normal(ks[1], (d,))
    g = jax.random.normal(ks[2], (d,))
    mask = (jnp.arange(d) % 2).astype(jnp.float32)
    c = jax.random.normal(ks[3], (p,))
    act = (jax.random.uniform(ks[4], (p,)) > 0.3).astype(jnp.float32)
    s = jnp.abs(jax.random.normal(ks[5], (p,)))
    gam = jnp.abs(jax.random.normal(ks[6], (p,)))
    kw = dict(eta_z=0.05, eta_s=0.05, eta_dual=0.05, rho2=1.0)

    got = ops.fused_cut_round(a, v, g, mask, c, act, s, gam,
                              impl="pallas", **kw)
    want = ops.fused_cut_round(a, v, g, mask, c, act, s, gam,
                               impl="ref", **kw)
    for x, y, name in zip(got, want, ("v_new", "cv", "s_new", "gamma")):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5, err_msg=name)

    def loss(impl):
        return lambda a, v, s, gam: sum(
            jnp.sum(o ** 2) for o in ops.fused_cut_round(
                a, v, g, mask, c, act, s, gam, impl=impl, **kw))

    gk = jax.grad(loss("pallas"), argnums=(0, 1, 2, 3))(a, v, s, gam)
    gr = jax.grad(loss("ref"), argnums=(0, 1, 2, 3))(a, v, s, gam)
    for x, y, name in zip(gk, gr, ("da", "dv", "ds", "dgamma")):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5, err_msg=name)
