"""Federated LLM trilevel step + sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.data.synthetic import make_token_stream
from repro.fed import (FedHyper, afto_llm_step, cut_refresh_llm,
                       init_fed_state, param_specs)
from repro.models import init_params
from repro.utils.tree import tree_any_nan

N, B, S = 4, 2, 32


def _abstract_mesh(shape, names):
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        pytest.skip("AbstractMesh(shape, axis_names) needs newer jax")


def _setup(cut_mode="sketch"):
    cfg = reduced(get_config("llama3-8b"))
    hyper = FedHyper(n_workers=N, cut_mode=cut_mode, sketch_r=128,
                     p_max=2, k_inner=1, remat=False)
    state = init_fed_state(cfg, hyper, jax.random.PRNGKey(0), B, S)
    toks = jnp.asarray(make_token_stream(cfg.vocab_size, N * B, S + 1)
                       ).reshape(N, B, S + 1)
    batch = {"tokens": toks, "val_tokens": toks}
    return cfg, hyper, state, batch


@pytest.mark.parametrize("cut_mode", ["sketch", "exact"])
def test_afto_llm_step_and_refresh(cut_mode):
    cfg, hyper, state, batch = _setup(cut_mode)
    active = jnp.ones((N,), jnp.float32)
    state = afto_llm_step(cfg, hyper, state, batch, active)
    state = cut_refresh_llm(cfg, hyper, state, batch)
    state = afto_llm_step(cfg, hyper, state, batch, active)
    assert float(jnp.sum(state.cuts.active)) >= 1
    assert float(jnp.sum(state.cuts_i.active)) >= 1
    assert not bool(tree_any_nan(state.X3))
    assert not bool(tree_any_nan(state.z3))
    assert int(state.t) == 2


def test_inactive_workers_frozen():
    cfg, hyper, state, batch = _setup()
    active = jnp.array([1.0, 0.0, 0.0, 1.0])
    new = afto_llm_step(cfg, hyper, state, batch, active)
    for leaf0, leaf1 in zip(jax.tree.leaves(state.X3),
                            jax.tree.leaves(new.X3)):
        # inactive worker rows unchanged
        np.testing.assert_array_equal(np.asarray(leaf0[1]),
                                      np.asarray(leaf1[1]))
        np.testing.assert_array_equal(np.asarray(leaf0[2]),
                                      np.asarray(leaf1[2]))


def test_param_specs_rules():
    mesh = _abstract_mesh((4, 4), ("data", "model"))
    cfg = reduced(get_config("mixtral-8x22b"))
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = param_specs(params, mesh)
    flat = {jax.tree_util.keystr(k): v for k, v
            in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]}
    # embedding sharded over vocab
    assert flat["['embed']"] == P("model", None)
    # attention wq: (R, d, H, hd) -> heads over model
    wq_keys = [k for k in flat if "wq" in k]
    assert all(flat[k] == P(None, None, "model", None) for k in wq_keys)
    # MoE experts over model: (R, E, d, f)
    moe_wi = [k for k in flat if "'moe'" in k and "'wi'" in k]
    assert moe_wi and all(flat[k] == P(None, "model", None, None)
                          for k in moe_wi)


def test_param_specs_divisibility_fallback():
    mesh = _abstract_mesh((2, 16), ("data", "model"))
    cfg = reduced(get_config("xlstm-125m"))  # 4 heads < 16-way model axis
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = param_specs(params, mesh)
    for path, spec in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]:
        key = jax.tree_util.keystr(path)
        if "wq" in key:   # (R, d=256, H=4, hd) — H not divisible by 16
            assert spec == P(None, None, None, None), (key, spec)


def test_worker_stack_axis():
    mesh = _abstract_mesh((4, 4), ("data", "model"))
    cfg = reduced(get_config("llama3-8b"))
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    stacked = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((4,) + x.shape, x.dtype), params)
    specs = param_specs(stacked, mesh, stack_axes=("data",))
    for path, spec in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]:
        assert spec[0] == "data", (jax.tree_util.keystr(path), spec)


def test_sketch_vs_exact_cut_agreement():
    """Sketched cut values approximate exact ones (same trajectory seed).

    This is the fidelity check for the beyond-paper sketched mu-cuts."""
    from repro.fed.trilevel_llm import eval_llm_cuts
    cfg, hyper_s, state_s, batch = _setup("sketch")
    _, hyper_e, state_e, _ = _setup("exact")
    active = jnp.ones((N,), jnp.float32)
    for st, hy in ((state_s, hyper_s), (state_e, hyper_e)):
        pass
    state_s = cut_refresh_llm(cfg, hyper_s, state_s, batch)
    state_e = cut_refresh_llm(cfg, hyper_e, state_e, batch)
    val_s = eval_llm_cuts(hyper_s, state_s.cuts, state_s.z1, state_s.z2,
                          state_s.z3, state_s.X2, state_s.X3,
                          hyper_s.seed_ii)
    val_e = eval_llm_cuts(hyper_e, state_e.cuts, state_e.z1, state_e.z2,
                          state_e.z3, state_e.X2, state_e.X3,
                          hyper_e.seed_ii)
    # identical states at t=0 -> the *active* slot values should be close
    # in relative terms (JL distortion of the sketch)
    a_s = float(val_s[np.argmax(np.asarray(state_s.cuts.active))])
    a_e = float(val_e[np.argmax(np.asarray(state_e.cuts.active))])
    assert np.isfinite(a_s) and np.isfinite(a_e)
    if abs(a_e) > 1e-3:
        assert abs(a_s - a_e) / abs(a_e) < 0.5


def test_fed_state_checkpoint_roundtrip(tmp_path):
    """Production resume path: the full FedLLMState (params, duals, cut
    sets, counters) roundtrips through the checkpoint layer."""
    import numpy as np
    from repro.checkpoint import load_checkpoint, save_checkpoint

    cfg, hyper, state, batch = _setup("sketch")
    state = afto_llm_step(cfg, hyper, state, batch,
                          jnp.ones((N,), jnp.float32))
    save_checkpoint(str(tmp_path / "fed"), state, step=1)
    restored = load_checkpoint(str(tmp_path / "fed"), state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert int(restored.t) == 1
