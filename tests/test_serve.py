"""launch/serve.py `fed` subcommand: the async-runtime front end.

Covers the serve-level contract the CI smoke step drives: the
master + N in-process workers round trip, the HTTP status endpoint,
and the CLI's convergence gate / legacy `decode` routing.
"""
import json
import urllib.request

import pytest

from repro.launch import serve as serve_lib


def _fed_args(**overrides):
    base = dict(problem="quadratic", workers=2, dim=3, seed=0, iters=30,
                metrics_every=10, transport="inproc", port=0,
                status_port=-1, accept_timeout=0.0, death_timeout=10.0,
                min_iter_time=0.0, ckpt_dir=None, ckpt_every=0,
                resume=False, stream=False, adapt_arrivals=False)
    base.update(overrides)
    import argparse
    return argparse.Namespace(**base)


def test_run_fed_inproc_round_trip():
    """Master + 2 in-process workers converge through the serve API."""
    result, status_server = serve_lib.run_fed(_fed_args())
    assert status_server is None
    gaps = result.history["gap_sq"]
    assert gaps[-1] < gaps[0]
    # the recorded live arrival process covers the whole run
    assert result.arrivals.n_iterations == 30


def test_status_endpoint_serves_master_counters():
    """GET /status returns the master's live JSON counters."""
    seen = {}

    def probe(master):
        srv = serve_lib.start_status_server(master, 0)
        port = srv.server_address[1]
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/status", timeout=10) as r:
            seen["status"] = json.loads(r.read())
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{port}/nope", timeout=10)
        srv.shutdown()

    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime import run_async
    problem, hyper = problems_lib.build("quadratic", n_workers=2)
    result = run_async(problem, hyper, n_iterations=8, metrics_every=4,
                       master_hook=probe)
    # probed before the loop started
    assert seen["status"]["t"] == 0
    assert seen["status"]["n_iterations"] == 8
    assert seen["status"]["done"] is False
    assert result.history["gap_sq"]


def test_status_endpoint_reports_per_worker_liveness():
    """/status carries the fault-layer's per-worker liveness view:
    last-heartbeat age, session epoch, staleness and the dead flag."""
    seen = {}

    def probe(master):
        srv = serve_lib.start_status_server(master, 0)
        port = srv.server_address[1]
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/status", timeout=10) as r:
            seen["status"] = json.loads(r.read())
        srv.shutdown()

    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime import run_async
    problem, hyper = problems_lib.build("quadratic", n_workers=3)
    run_async(problem, hyper, n_iterations=6, metrics_every=3,
              master_hook=probe)
    st = seen["status"]
    workers = st["workers"]
    assert [w["worker"] for w in workers] == [0, 1, 2]
    for w in workers:
        assert w["alive"] is True and w["dead"] is False
        assert w["last_seen_age"] >= 0.0
        assert w["epoch"] == 0 and w["staleness"] >= 0
    assert st["deaths"] == 0 and st["rejoins"] == 0
    assert st["corrupt_frames"] == 0 and st["resumed_from"] is None


def test_run_fed_streamed_inproc_round_trip():
    """`--stream` end to end over the serve API: workers synthesize
    their own batches and the recorded schedule carries the effective
    (s, tau) audit columns."""
    result, _ = serve_lib.run_fed(_fed_args(stream=True,
                                            adapt_arrivals=True))
    sched = result.arrivals
    assert sched.n_iterations == 30
    assert sched.s_eff is not None and sched.tau_eff is not None
    assert (sched.tau_eff >= 1).all()


def test_fed_cli_streamed_run_gates_on_replay(capsys):
    """The streamed CLI path exits 0 only through the replay gate."""
    rc = serve_lib.main(["fed", "--workers", "2", "--iters", "20",
                         "--metrics-every", "5", "--stream"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "streamed replay gate" in out
    assert "EXCEEDS" not in out


def test_status_carries_recent_arrival_rows():
    """The master's status dict (the /status payload) includes the
    recorder's recent arrival rows with the effective-(s, tau) pair."""
    held = {}

    from repro.fed.runtime import problems as problems_lib
    from repro.fed.runtime import run_async
    problem, hyper = problems_lib.build("quadratic", n_workers=2)
    run_async(problem, hyper, n_iterations=6, metrics_every=3,
              master_hook=lambda m: held.setdefault("master", m))
    rows = held["master"].status["arrivals"]
    assert rows and rows[-1]["t"] == 6
    for r in rows:
        assert set(r) == {"t", "arrived", "s_eff", "tau_eff",
                          "max_staleness"}
        assert r["s_eff"] == hyper.s_active
        assert r["tau_eff"] == hyper.tau


def test_fed_cli_gates_on_convergence(capsys):
    rc = serve_lib.main(["fed", "--workers", "2", "--iters", "30",
                         "--metrics-every", "10"])
    assert rc == 0
    out = capsys.readouterr().out
    records = [json.loads(line) for line in out.splitlines()
               if line.startswith("{")]
    assert [r["t"] for r in records] == [10, 20, 30]
    assert all("gap_sq" in r and "max_staleness" in r for r in records)
    assert "decreasing" in out


def test_main_routes_bare_flags_to_decode():
    """The historical CLI surface (no subcommand) still means decode."""
    with pytest.raises(SystemExit):
        # decode's parser rejects the unknown flag — proving the route
        serve_lib.main(["--definitely-not-a-flag"])
