"""Roofline parser + analytic cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import roofline as rl
from repro.models.config import active_param_count, param_count, step_flops


def test_shape_bytes_parser():
    assert rl._shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert rl._shape_bytes("bf16[4096]") == 4096 * 2
    assert rl._shape_bytes("(f32[8], bf16[8])") == 8 * 4 + 8 * 2
    assert rl._shape_bytes("pred[]") == 0 or rl._shape_bytes("pred[]") == 1


def test_collective_bytes_from_real_hlo():
    def f(x):
        return jnp.sum(jnp.tanh(x @ x.T))

    # trivially no collectives on one device
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)) \
        .compile()
    out = rl.collective_bytes(c.as_text())
    assert out["count"] == 0


def test_param_count_matches_model_names():
    # xlstm-125m omitted: the assigned spec fixes d_ff=0 (no FFN blocks)
    # which yields ~67M params for 12L/768d — the 125M name assumes the
    # paper's projection/FFN factors the assignment's d_ff=0 excludes.
    expect = {"llama3-405b": 405e9, "llama3-8b": 8e9, "yi-34b": 34e9,
              "mixtral-8x22b": 141e9, "kimi-k2-1t-a32b": 1.0e12,
              "jamba-v0.1-52b": 52e9, "gemma3-12b": 12e9}
    for name, n in expect.items():
        got = param_count(get_config(name))["total"]
        assert abs(got - n) / n < 0.15, (name, got, n)


def test_active_params_moe():
    cfg = get_config("kimi-k2-1t-a32b")
    active = active_param_count(cfg)
    assert abs(active - 32e9) / 32e9 < 0.25   # "a32b"
    assert active < param_count(cfg)["total"] / 10


def test_step_flops_scaling():
    cfg = get_config("llama3-8b")
    f1 = step_flops(cfg, 1, 1024, training=False)
    f2 = step_flops(cfg, 2, 1024, training=False)
    assert abs(f2["total"] / f1["total"] - 2.0) < 0.05
    ftr = step_flops(cfg, 1, 1024, training=True)
    assert abs(ftr["total"] / f1["total"] - 3.0) < 0.01


def test_step_flops_6nd_consistency():
    """fwd flops ~ 2*N*D for a dense arch at short seq."""
    cfg = get_config("llama3-8b")
    tokens = 4 * 1024
    f = step_flops(cfg, 4, 1024, training=False)
    n = active_param_count(cfg)
    ratio = f["fwd_total"] / (2.0 * n * tokens)
    assert 0.9 < ratio < 1.3, ratio


def test_decode_flops_much_smaller():
    cfg = get_config("llama3-8b")
    dec = step_flops(cfg, 8, 1, training=False, kv_len=32768)
    pre = step_flops(cfg, 8, 32768, training=False)
    assert dec["total"] < pre["total"] / 1000
