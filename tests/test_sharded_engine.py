"""Worker-mesh sharded trajectory engine: fake-device conformance suite.

Every test runs the shard_map-distributed engine on a CPU fake-device
mesh (2 and 4 workers; `tests/conftest.py` forces 8 fake devices before
jax initializes) and asserts the sharded trajectories match the
single-device compiled scan to f32 tolerance STEP-BY-STEP — through cut
refresh, slot eviction and straggler-masked iterations — plus the
retrace gate (warm sharded BUILD_COUNTS stay at 1) and the no-reflatten
guard on the sharded step.  The hypothesis property randomizes arrival
schedules and cut-maintenance interleavings (t_pre / p_max / t1 / S /
tau) over both mesh widths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (make_hyper, make_quadratic_problem, make_schedules,
                      make_straggler_cfg)
from repro.core import run, run_scanned, run_swept
from repro.core import engine as engine_lib
from repro.core import sharded as sharded_lib
from repro.core.scheduler import StragglerScheduler
from repro.launch.mesh import make_worker_mesh

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 (fake) devices; tests/conftest.py forces 8 "
           "unless XLA_FLAGS was already set")

MESH_WIDTHS = (2, 4)


def _mesh(w):
    return make_worker_mesh(w)


def _assert_states_close(a, b, rtol=5e-5, atol=1e-6):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=rtol, atol=atol)


def _assert_histories_close(h_ref, h_sh, rtol=5e-4, atol=1e-6):
    """Step-by-step: every recorded iteration, every metric."""
    assert list(h_ref["t"]) == list(h_sh["t"])
    np.testing.assert_allclose(h_ref["gap_sq"], h_sh["gap_sq"],
                               rtol=rtol, atol=atol)
    np.testing.assert_array_equal(h_ref["n_cuts_i"], h_sh["n_cuts_i"])
    np.testing.assert_array_equal(h_ref["n_cuts_ii"], h_sh["n_cuts_ii"])
    np.testing.assert_allclose(h_ref["sim_time"], h_sh["sim_time"])
    np.testing.assert_allclose(h_ref["max_staleness"],
                               h_sh["max_staleness"])


# ---------------------------------------------------------------------------
# scan conformance: step-by-step across refresh / eviction / stragglers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", MESH_WIDTHS)
def test_sharded_scan_matches_single_device(w):
    """metrics_every=1 records EVERY iteration, so gap/cut-count parity
    is a per-step check through refresh and straggler-masked steps."""
    prob = make_quadratic_problem()
    hyper = make_hyper()
    schedule = StragglerScheduler(make_straggler_cfg()).precompute(40)

    def metrics(state):
        return {"z1_norm_sq": jnp.sum(state.z1 ** 2)}

    ref = run_scanned(prob, hyper, schedule, metrics_fn=metrics,
                      metrics_every=1)
    sh = run_scanned(prob, hyper, schedule, metrics_fn=metrics,
                     metrics_every=1, mesh=_mesh(w))
    _assert_states_close(ref.state, sh.state)
    _assert_histories_close(ref.history, sh.history)
    np.testing.assert_allclose(ref.history["z1_norm_sq"],
                               sh.history["z1_norm_sq"],
                               rtol=5e-5, atol=1e-7)


@pytest.mark.parametrize("w", MESH_WIDTHS)
def test_sharded_scan_through_eviction(w):
    """p_max=2 with a refresh every 2 iterations forces slot evictions
    AND Eq. 25 drops while heavy stragglers mask most workers."""
    prob = make_quadratic_problem()
    hyper = make_hyper(s_active=2, tau=4, k_inner=2, p_max=2, t_pre=2)
    schedule = StragglerScheduler(make_straggler_cfg(
        s_active=2, tau=4, n_stragglers=2, straggler_slowdown=10.0,
        seed=3)).precompute(30)

    ref = run_scanned(prob, hyper, schedule, metrics_every=1)
    sh = run_scanned(prob, hyper, schedule, metrics_every=1, mesh=_mesh(w))
    _assert_states_close(ref.state, sh.state)
    _assert_histories_close(ref.history, sh.history)
    # evictions actually happened (ages beyond the first p_max adds)
    assert int(np.asarray(sh.state.cuts_ii.age).max()) >= 2 * hyper.p_max


def test_sharded_runner_dispatch():
    """runner.run(mode='scan'|'sweep', mesh=...) routes to the sharded
    engines; mesh with eager mode is rejected."""
    prob = make_quadratic_problem()
    hyper = make_hyper()
    cfg = make_straggler_cfg()
    mesh = _mesh(2)
    ref = run(prob, hyper, scheduler_cfg=cfg, n_iterations=20,
              metrics_every=5)
    sh = run(prob, hyper, scheduler_cfg=cfg, n_iterations=20,
             metrics_every=5, mesh=mesh)
    np.testing.assert_allclose(ref.history["gap_sq"],
                               sh.history["gap_sq"], rtol=5e-4, atol=1e-6)
    sw = run(prob, hyper, scheduler_cfg=cfg, n_iterations=20,
             metrics_every=5, mode="sweep", seeds=(0, 1), mesh=mesh)
    np.testing.assert_allclose(ref.history["gap_sq"],
                               sw.run(0).history["gap_sq"],
                               rtol=5e-4, atol=1e-6)
    with pytest.raises(ValueError):
        run(prob, hyper, n_iterations=4, mode="eager", mesh=mesh)


# ---------------------------------------------------------------------------
# sweep conformance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", MESH_WIDTHS)
def test_sharded_sweep_matches_single_device(w):
    prob = make_quadratic_problem()
    hyper = make_hyper()
    scheds = make_schedules(25, (0, 1, 2))
    ref = run_swept(prob, hyper, scheds, metrics_every=5)
    sh = run_swept(prob, hyper, scheds, metrics_every=5, mesh=_mesh(w))
    _assert_states_close(ref.state, sh.state)
    np.testing.assert_allclose(ref.history["gap_sq"],
                               sh.history["gap_sq"], rtol=5e-4, atol=1e-6)
    np.testing.assert_array_equal(ref.history["n_cuts_ii"],
                                  sh.history["n_cuts_ii"])


def test_sharded_sweep_hypers_and_states():
    """Per-run hyper scalars and caller-stacked states ride the sharded
    sweep; each row matches the corresponding single-device scan."""
    from repro.core import afto as afto_lib
    from repro.utils.tree import tree_stack

    hyper = make_hyper()
    prob = make_quadratic_problem()
    scheds = make_schedules(15, (0, 0))
    mesh = _mesh(2)
    sw = run_swept(prob, hyper, scheds, metrics_every=5, mesh=mesh,
                   sweep_hypers={"eta_z": [0.05, 0.01]})
    for r, eta_z in enumerate((0.05, 0.01)):
        single = run_scanned(prob, dataclasses.replace(hyper, eta_z=eta_z),
                             scheds[r], metrics_every=5)
        np.testing.assert_allclose(single.history["gap_sq"],
                                   sw.run(r).history["gap_sq"],
                                   rtol=5e-4, atol=1e-6)

    states = tree_stack([afto_lib.init_state(prob, hyper)] * 2)
    sw2 = run_swept(prob, hyper, scheds, metrics_every=5, states=states,
                    mesh=mesh)
    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree.leaves(states))
    np.testing.assert_allclose(sw.run(0).history["gap_sq"],
                               sw2.run(0).history["gap_sq"],
                               rtol=5e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# guards: mesh validation, donation, retrace, no-reflatten
# ---------------------------------------------------------------------------

def test_sharded_rejects_bad_mesh():
    from jax.sharding import Mesh

    prob = make_quadratic_problem()
    schedule = StragglerScheduler(make_straggler_cfg()).precompute(4)
    with pytest.raises(ValueError):       # 4 workers over 3 shards
        run_scanned(prob, make_hyper(), schedule, mesh=_mesh(3))
    wrong = Mesh(np.asarray(jax.devices()[:2]), ("data",))
    with pytest.raises(ValueError):       # no "worker" axis
        run_scanned(prob, make_hyper(), schedule, mesh=wrong)
    with pytest.raises(ValueError):       # more shards than devices
        make_worker_mesh(jax.device_count() + 1)


def test_sharded_caller_state_not_donated():
    from repro.core import afto as afto_lib

    prob = make_quadratic_problem()
    hyper = make_hyper()
    schedule = StragglerScheduler(make_straggler_cfg()).precompute(10)
    state = afto_lib.init_state(prob, hyper)
    res = run_scanned(prob, hyper, schedule, metrics_every=5, state=state,
                      mesh=_mesh(2))
    assert np.all(np.isfinite(np.asarray(state.z1)))
    assert np.all(np.isfinite(res.history["gap_sq"]))
    # the returned polytope is reassembled to the canonical global layout
    assert res.state.cuts_ii.spec == state.cuts_ii.spec
    assert res.state.cuts_ii.a.shape == state.cuts_ii.a.shape


@pytest.mark.parametrize("w", MESH_WIDTHS)
def test_sharded_warm_build_counts_stay_at_one(w):
    """Retrace gate extension: a warm sharded scan/sweep must reuse the
    compiled trajectory — the *_sharded BUILD_COUNTS rise exactly once
    per (problem, mesh) and stay flat across repeat + fresh-schedule
    calls (same contract as benchmarks/retrace_gate.py)."""
    prob = make_quadratic_problem(seed=17)       # fresh cache keys
    hyper = make_hyper()
    mesh = _mesh(w)
    schedule = StragglerScheduler(make_straggler_cfg()).precompute(12)

    before = engine_lib.BUILD_COUNTS["scan_sharded"]
    run_scanned(prob, hyper, schedule, metrics_every=6, mesh=mesh)
    assert engine_lib.BUILD_COUNTS["scan_sharded"] == before + 1
    run_scanned(prob, hyper, schedule, metrics_every=6, mesh=mesh)
    run_scanned(prob, hyper,
                StragglerScheduler(make_straggler_cfg(seed=9))
                .precompute(12), metrics_every=6, mesh=mesh)
    assert engine_lib.BUILD_COUNTS["scan_sharded"] == before + 1

    before = engine_lib.BUILD_COUNTS["sweep_sharded"]
    scheds = make_schedules(12, (0, 1))
    run_swept(prob, hyper, scheds, metrics_every=6, mesh=mesh)
    assert engine_lib.BUILD_COUNTS["sweep_sharded"] == before + 1
    run_swept(prob, hyper, make_schedules(12, (5, 6)), metrics_every=6,
              mesh=mesh)
    assert engine_lib.BUILD_COUNTS["sweep_sharded"] == before + 1


def test_no_reflatten_on_sharded_path(monkeypatch):
    """`flat_spec` / `flatten_cuts` never execute while building or
    running the sharded trajectory: the shard-local column views are
    consumed as stored (host-side shard/unshard included), and the only
    flatten is the new cut row's `flatten_coeffs`."""
    from repro.core import cuts as cuts_lib

    calls = []
    orig_spec, orig_flat = cuts_lib.flat_spec, cuts_lib.flatten_cuts
    monkeypatch.setattr(
        cuts_lib, "flat_spec",
        lambda *a, **k: (calls.append("flat_spec"), orig_spec(*a, **k))[1])
    monkeypatch.setattr(
        cuts_lib, "flatten_cuts",
        lambda *a, **k: (calls.append("flatten_cuts"),
                         orig_flat(*a, **k))[1])

    prob = make_quadratic_problem(seed=23)       # fresh cache key: builds
    hyper = make_hyper()
    schedule = StragglerScheduler(make_straggler_cfg()).precompute(10)
    run_scanned(prob, hyper, schedule, metrics_every=5, mesh=_mesh(2))
    assert calls == []


# ---------------------------------------------------------------------------
# scheduler / traffic helpers
# ---------------------------------------------------------------------------

def test_schedule_worker_shards_partition():
    schedule = StragglerScheduler(make_straggler_cfg()).precompute(16)
    shards = schedule.worker_shards(2)
    assert shards.shape == (2, 16, 2)
    np.testing.assert_array_equal(
        np.concatenate([shards[0], shards[1]], axis=1), schedule.active)
    with pytest.raises(ValueError):
        schedule.worker_shards(3)


def test_traffic_record_positive():
    prob = make_quadratic_problem()
    hyper = make_hyper()
    from repro.core import afto as afto_lib
    state = jax.eval_shape(lambda: afto_lib.init_state(prob, hyper))
    rec = sharded_lib.traffic_record(state.cuts_ii.spec, hyper)
    assert rec["step_bytes"] > 0
    assert rec["refresh_bytes"] > rec["step_bytes"]


# ---------------------------------------------------------------------------
# hypothesis: random schedules x maintenance interleavings x mesh width
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                        # pragma: no cover
    HAVE_HYPOTHESIS = False


def _interleaving_body(w, seed, s_active, tau, t_pre, p_max, t1):
    """Sharded == single-device for an arbitrary (schedule, maintenance)
    interleaving: the arrival process seed randomizes WHICH workers are
    masked when, and (t_pre, t1, p_max) randomize when cuts are added,
    evicted and dropped relative to those masks."""
    prob = make_quadratic_problem()
    hyper = make_hyper(s_active=s_active, tau=tau, k_inner=2,
                       p_max=p_max, t_pre=t_pre, t1=t1)
    schedule = StragglerScheduler(make_straggler_cfg(
        s_active=s_active, tau=tau, n_stragglers=2,
        straggler_slowdown=10.0, seed=seed)).precompute(14)
    ref = run_scanned(prob, hyper, schedule, metrics_every=1)
    sh = run_scanned(prob, hyper, schedule, metrics_every=1,
                     mesh=_mesh(w))
    _assert_states_close(ref.state, sh.state, rtol=1e-4, atol=1e-6)
    _assert_histories_close(ref.history, sh.history, rtol=1e-3,
                            atol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(w=st.sampled_from(MESH_WIDTHS),
           seed=st.integers(0, 2 ** 16),
           s_active=st.sampled_from((2, 4)),
           tau=st.sampled_from((3, 6)),
           t_pre=st.sampled_from((2, 4)),
           p_max=st.sampled_from((2, 4)),
           t1=st.sampled_from((6, 100)))
    def test_sharded_interleaving_property(w, seed, s_active, tau, t_pre,
                                           p_max, t1):
        _interleaving_body(w, seed, s_active, tau, t_pre, p_max, t1)
else:                                       # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_sharded_interleaving_property():
        pass
