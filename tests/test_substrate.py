"""Substrate layers: optimizers, schedules, checkpoint, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.checkpoint.io import latest_step
from repro.data.loader import ShardedLoader
from repro.data.synthetic import (REGRESSION_SPECS, make_digits,
                                  make_regression, make_token_stream)
from repro.optim import adamw, clip_by_global_norm, chain, sgd
from repro.optim.optimizers import apply_updates
from repro.optim.schedules import warmup_cosine


def test_adamw_converges_on_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw(0.1)
    state = opt.init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-3


def test_sgd_and_clip_chain():
    params = {"w": jnp.zeros(4)}
    opt = chain(clip_by_global_norm(1.0), sgd(0.5))
    state = opt.init(params)
    g = {"w": jnp.full((4,), 100.0)}
    upd, state = opt.update(g, state, params)
    gn = float(jnp.linalg.norm(upd["w"]))
    assert abs(gn - 0.5) < 1e-5      # clipped to 1.0 then scaled by lr


def test_warmup_cosine_schedule():
    sch = warmup_cosine(1.0, 10, 100)
    assert float(sch(0)) < 0.2
    assert abs(float(sch(10)) - 1.0) < 0.15
    assert float(sch(99)) < 0.2


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.float32(3.5)}}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, tree, step=7)
    save_checkpoint(d, tree, step=9)
    assert latest_step(d) == 9
    restored = load_checkpoint(d, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_retention(tmp_path):
    tree = {"a": jnp.zeros(2)}
    d = str(tmp_path / "ckpt")
    for s in range(6):
        save_checkpoint(d, tree, step=s, keep=3)
    steps = sorted(os.listdir(d))
    assert len(steps) == 3 and steps[-1] == "step_00000005"


@pytest.mark.parametrize("name", list(REGRESSION_SPECS))
def test_regression_shapes(name):
    data = make_regression(name, n_workers=4)
    n, d = REGRESSION_SPECS[name]
    assert data.x_train.shape[0] == 4
    assert data.x_train.shape[2] == d
    assert data.x_test.shape[1] == d
    assert np.isfinite(data.x_train).all()


def test_digits_two_domains_differ():
    data = make_digits(2, n_pretrain_per=8, n_finetune_per=8, n_test=8)
    assert data.x_pretrain.shape[2:] == (32, 32, 1)
    # domains must be statistically distinguishable
    assert abs(data.x_pretrain.std() - data.x_finetune.std()) > 0.01


def test_token_stream_zipf():
    toks = make_token_stream(1000, 4, 256, seed=0)
    assert toks.shape == (4, 256) and toks.max() < 1000
    # zipf: token 0 should be the most frequent
    vals, counts = np.unique(toks, return_counts=True)
    assert vals[np.argmax(counts)] == 0


def test_sharded_loader_epochs():
    arrays = {"x": np.arange(10), "y": np.arange(10) * 2}
    loader = ShardedLoader(arrays, batch_size=4, seed=0)
    batches = list(loader)
    assert len(batches) == 2
    assert all(b["x"].shape == (4,) for b in batches)
    np.testing.assert_array_equal(batches[0]["y"], batches[0]["x"] * 2)


def _epoch_order(loader):
    return np.concatenate([b["x"] for b in loader])


def test_sharded_loader_per_epoch_shuffles():
    """Regression for the shared-stateful-rng shuffle: epoch k's
    permutation must be a pure function of (seed, k) — epochs differ
    from each other, replay identically across loader instances, and
    concurrent iterators cannot scramble each other's order."""
    arrays = {"x": np.arange(32)}
    a = ShardedLoader(arrays, batch_size=8, seed=5)
    b = ShardedLoader(arrays, batch_size=8, seed=5)

    ep_a = [_epoch_order(a) for _ in range(3)]
    # epochs are distinct shuffles...
    assert not np.array_equal(ep_a[0], ep_a[1])
    assert not np.array_equal(ep_a[1], ep_a[2])
    # ...each a permutation of the data...
    for ep in ep_a:
        np.testing.assert_array_equal(np.sort(ep), np.arange(32))
    # ...reproduced exactly by a fresh loader with the same seed
    for ep, ep2 in zip(ep_a, (_epoch_order(b) for _ in range(3))):
        np.testing.assert_array_equal(ep, ep2)
    # a different seed is a different shuffle sequence
    other = _epoch_order(ShardedLoader(arrays, batch_size=8, seed=6))
    assert not np.array_equal(ep_a[0], other)


def test_sharded_loader_interleaved_iterators_stable():
    """Two iterators consumed in lockstep see epoch 0 and epoch 1 orders
    (claimed at iter() time), identical to sequential consumption — the
    old shared generator gave interleaving-dependent permutations."""
    arrays = {"x": np.arange(24)}
    seq = ShardedLoader(arrays, batch_size=6, seed=9)
    ep0, ep1 = _epoch_order(seq), _epoch_order(seq)

    inter = ShardedLoader(arrays, batch_size=6, seed=9)
    it0, it1 = iter(inter), iter(inter)
    got0, got1 = [], []
    for b0, b1 in zip(it0, it1):
        got0.append(b0["x"])
        got1.append(b1["x"])
    np.testing.assert_array_equal(np.concatenate(got0), ep0)
    np.testing.assert_array_equal(np.concatenate(got1), ep1)
