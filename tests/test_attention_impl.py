"""Chunked (flash-style) attention == naive attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attend, attend_chunked, \
    causal_window_mask


@pytest.mark.parametrize("s,h,hkv,hd,bq,bk", [
    (64, 4, 2, 16, 16, 16),
    (100, 4, 4, 32, 32, 16),   # unaligned seq
    (128, 8, 2, 16, 64, 64),
])
@pytest.mark.parametrize("window", [0, 40])
def test_chunked_matches_naive(s, h, hkv, hd, bq, bk, window):
    b = 2
    key = jax.random.PRNGKey(s + window)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    mask = causal_window_mask(pos, pos, window)[:, None]
    want = attend(q, k, v, mask)
    got = attend_chunked(q, k, v, causal=True, window=window,
                         block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_chunked_model_forward_equivalence():
    from repro.configs import get_config, reduced
    from repro.models import forward, init_params
    from repro.data.synthetic import make_token_stream

    cfg = reduced(get_config("llama3-8b"))
    cfg_c = dataclasses.replace(cfg, attn_impl="chunked",
                                attn_block_q=16, attn_block_k=16)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(make_token_stream(cfg.vocab_size, 2, 48, seed=0))
    a, _, _ = forward(cfg, params, toks)
    b, _, _ = forward(cfg_c, params, toks)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-2, atol=2e-2)
