"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# cut_eval
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p,d,block_d", [
    (1, 128, 128), (5, 3000, 1024), (8, 2048, 2048), (13, 5000, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cut_eval_sweep(p, d, block_d, dtype):
    key = jax.random.PRNGKey(p * 7 + d)
    ks = jax.random.split(key, 4)
    a = (jax.random.normal(ks[0], (p, d)) * 0.1).astype(dtype)
    v = jax.random.normal(ks[1], (d,)).astype(dtype)
    c = jax.random.normal(ks[2], (p,))
    act = (jax.random.uniform(ks[3], (p,)) > 0.3).astype(jnp.float32)
    # impl forced: the auto route picks the identical-math jnp mat-vec
    # off-TPU, which would reduce this to ref-vs-ref
    got = ops.cut_eval(a, v, c, act, block_d=block_d, impl="pallas")
    want = ref.cut_eval_ref(a, v, c, act)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_cut_eval_custom_vjp_matches_ref_grads():
    """The kernel's custom VJP must agree with grads of the jnp oracle
    for every differentiable operand (a, v, c)."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    p, d = 5, 300
    a = jax.random.normal(ks[0], (p, d)) * 0.1
    v = jax.random.normal(ks[1], (d,))
    c = jax.random.normal(ks[2], (p,))
    act = (jax.random.uniform(ks[3], (p,)) > 0.3).astype(jnp.float32)

    def loss_k(a, v, c):
        return jnp.sum(ops.cut_eval(a, v, c, act, impl="pallas") ** 2)

    def loss_r(a, v, c):
        return jnp.sum(ref.cut_eval_ref(a, v, c, act) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(a, v, c)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(a, v, c)
    for x, y in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)


def test_cut_eval_vmap_batches_kernel():
    """The sweep engine vmaps the kernel over a leading run axis."""
    key = jax.random.PRNGKey(4)
    r, p, d = 3, 4, 200
    a = jax.random.normal(key, (r, p, d)) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 1), (r, d))
    c = jnp.zeros((p,))
    act = jnp.ones((p,))
    got = jax.vmap(lambda a, v: ops.cut_eval(a, v, c, act,
                                             impl="pallas"))(a, v)
    want = jnp.einsum("rpd,rd->rp", a, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,h,hkv,hd,blk", [
    (64, 4, 2, 32, 16), (48, 4, 4, 64, 16), (128, 8, 2, 32, 32),
])
@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, h, hkv, hd, blk, window, dtype):
    b = 2
    key = jax.random.PRNGKey(s + h + window)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, hd)).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=blk, block_k=blk)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-3 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_unaligned_seq():
    """S not a multiple of the block: the wrapper pads causally."""
    b, s, h, hd = 1, 37, 2, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    got = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# mlstm chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("l,hd", [(8, 8), (16, 16), (32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunk_sweep(l, hd, dtype):
    b, h = 2, 3
    key = jax.random.PRNGKey(l + hd)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, l, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, h, l, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, h, l, hd)).astype(dtype)
    li = (jax.random.normal(ks[3], (b, h, l, 1)) * 0.5)
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, l, 1)) + 2.0)
    c0 = jnp.zeros((b, h, hd, hd))
    n0 = jnp.zeros((b, h, 1, hd))
    m0 = jnp.full((b, h, 1, 1), -1e9)
    got = ops.mlstm_chunk(q, k, v, li, lf, c0, n0, m0)
    want = ref.mlstm_chunk_ref(q, k, v, li, lf, c0, n0, m0)
    tol = 6e-3 if dtype == jnp.float32 else 6e-2
    for g, w, name in zip(got, want, ["y", "c", "n", "m"]):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=tol, atol=tol, err_msg=name)


def test_mlstm_sequence_carries_state():
    """Two chunks through the kernel == one pass of the jnp oracle over
    the full sequence (state carried across chunk boundary)."""
    from repro.models.xlstm import mlstm_chunk_body, init_mlstm_state
    b, h, s, hd = 1, 2, 32, 8
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    li = jax.random.normal(ks[3], (b, s, h)) * 0.5
    lf = jnp.asarray(jax.nn.log_sigmoid(
        jax.random.normal(ks[4], (b, s, h)) + 2.0))
    state = init_mlstm_state(b, h, hd)
    y_kernel, st_kernel = ops.mlstm_sequence(q, k, v, li, lf, state,
                                             chunk=16)
    # oracle: full-sequence single chunk
    y_ref, st_ref = mlstm_chunk_body(q, k, v, li, lf, state)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(st_kernel["c"]),
                               np.asarray(st_ref["c"]),
                               rtol=2e-2, atol=2e-2)
