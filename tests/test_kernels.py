"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# cut_eval
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p,d,block_d", [
    (1, 128, 128), (5, 3000, 1024), (8, 2048, 2048), (13, 5000, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cut_eval_sweep(p, d, block_d, dtype):
    key = jax.random.PRNGKey(p * 7 + d)
    ks = jax.random.split(key, 4)
    a = (jax.random.normal(ks[0], (p, d)) * 0.1).astype(dtype)
    v = jax.random.normal(ks[1], (d,)).astype(dtype)
    c = jax.random.normal(ks[2], (p,))
    act = (jax.random.uniform(ks[3], (p,)) > 0.3).astype(jnp.float32)
    # impl forced: the auto route picks the identical-math jnp mat-vec
    # off-TPU, which would reduce this to ref-vs-ref
    got = ops.cut_eval(a, v, c, act, block_d=block_d, impl="pallas")
    want = ref.cut_eval_ref(a, v, c, act)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_cut_eval_custom_vjp_matches_ref_grads():
    """The kernel's custom VJP must agree with grads of the jnp oracle
    for every differentiable operand (a, v, c)."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    p, d = 5, 300
    a = jax.random.normal(ks[0], (p, d)) * 0.1
    v = jax.random.normal(ks[1], (d,))
    c = jax.random.normal(ks[2], (p,))
    act = (jax.random.uniform(ks[3], (p,)) > 0.3).astype(jnp.float32)

    def loss_k(a, v, c):
        return jnp.sum(ops.cut_eval(a, v, c, act, impl="pallas") ** 2)

    def loss_r(a, v, c):
        return jnp.sum(ref.cut_eval_ref(a, v, c, act) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(a, v, c)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(a, v, c)
    for x, y in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)


def test_cut_eval_vmap_batches_kernel():
    """The sweep engine vmaps the kernel over a leading run axis."""
    key = jax.random.PRNGKey(4)
    r, p, d = 3, 4, 200
    a = jax.random.normal(key, (r, p, d)) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 1), (r, d))
    c = jnp.zeros((p,))
    act = jnp.ones((p,))
    got = jax.vmap(lambda a, v: ops.cut_eval(a, v, c, act,
                                             impl="pallas"))(a, v)
    want = jnp.einsum("rpd,rd->rp", a, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def _cut_operands(p, d, seed=0, active=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    a = jax.random.normal(ks[0], (p, d)) * (d ** -0.5)
    v = jax.random.normal(ks[1], (d,))
    c = jax.random.normal(ks[2], (p,))
    if active is None:
        active = (jax.random.uniform(ks[3], (p,)) > 0.3).astype(jnp.float32)
    w = jax.random.normal(ks[4], (p,))
    return a, v, c, active, w


def _quad_loss(impl, act, w):
    # quadratic so first grads depend on (a, v) and grad-of-grad is a
    # real second-order contraction
    return lambda a, v, c: 0.5 * jnp.sum(
        ops.cut_eval(a, v, c, act, impl=impl) ** 2 * w)


# (5, 300): quickstart-ish; (8, 4096): paper-scale P with two 2048-lane
# tiles so the grid accumulation carry is exercised
@pytest.mark.parametrize("p,d", [(5, 300), (8, 4096)])
def test_cut_eval_bwd_parity(p, d):
    """jax.grad through the kernel route (the hand-written rank-1 da /
    row-reduction dv kernels via the cut_ad transposes) == grads of the
    jnp oracle, for every differentiable operand."""
    a, v, c, act, w = _cut_operands(p, d)
    gk = jax.grad(_quad_loss("pallas", act, w), argnums=(0, 1, 2))(a, v, c)
    gr = jax.grad(_quad_loss("ref", act, w), argnums=(0, 1, 2))(a, v, c)
    for x, y, name in zip(gk, gr, ["da", "dv", "dc"]):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5, err_msg=name)


@pytest.mark.parametrize("p,d", [(5, 300), (8, 4096)])
def test_cut_eval_jvp_parity(p, d):
    """Forward-mode through the kernel route: the cut_ad primitives have
    real JVP rules (no impl="ref" fallback, no custom_vjp error)."""
    a, v, c, act, _ = _cut_operands(p, d)
    da = jax.random.normal(jax.random.PRNGKey(9), a.shape) * (d ** -0.5)
    dv = jax.random.normal(jax.random.PRNGKey(10), v.shape)

    def f(impl):
        return lambda a, v: ops.cut_eval(a, v, c, act, impl=impl)

    yk, tk = jax.jvp(f("pallas"), (a, v), (da, dv))
    yr, tr = jax.jvp(f("ref"), (a, v), (da, dv))
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("p,d", [(5, 300), (8, 4096)])
def test_cut_eval_grad_of_grad_parity(p, d):
    """Second order through the kernel route — the cut-refresh (Eq.
    23/24) shape that used to force impl="ref" on the inner-Lagrangian
    paths.  grad(||grad||^2) must match the oracle's."""
    a, v, c, act, w = _cut_operands(p, d)

    def gog(impl):
        loss = _quad_loss(impl, act, w)
        inner = lambda v: jnp.sum(jax.grad(loss, argnums=1)(a, v, c) ** 2)
        return jax.grad(inner)(v)

    got, want = gog("pallas"), gog("ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_cut_eval_bwd_masked_rows_zero_grads():
    """Evicted/inactive cut slots contribute nothing: their rows of da
    and their dc entries must be exactly zero through the kernel."""
    p, d = 6, 512
    active = jnp.array([1.0, 0.0, 1.0, 0.0, 0.0, 1.0])
    a, v, c, _, w = _cut_operands(p, d, seed=7, active=active)
    da, dv, dc = jax.grad(_quad_loss("pallas", active, w),
                          argnums=(0, 1, 2))(a, v, c)
    dead = np.asarray(active) == 0.0
    assert np.all(np.asarray(da)[dead] == 0.0)
    assert np.all(np.asarray(dc)[dead] == 0.0)
    # and the live rows match the oracle
    da_r, dv_r, dc_r = jax.grad(_quad_loss("ref", active, w),
                                argnums=(0, 1, 2))(a, v, c)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(dv_r),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(da), np.asarray(da_r),
                               rtol=1e-4, atol=1e-5)


def test_cut_eval_vmap_of_grad_sweep_axis():
    """The sweep engine differentiates vmapped runs: vmap(grad(kernel))
    must batch through the cut_ad primitives and match the oracle."""
    r, p, d = 3, 4, 256
    key = jax.random.PRNGKey(11)
    a = jax.random.normal(key, (r, p, d)) * (d ** -0.5)
    v = jax.random.normal(jax.random.fold_in(key, 1), (r, d))
    c = jnp.zeros((p,))
    act = jnp.ones((p,))

    def loss(impl):
        return lambda a, v: 0.5 * jnp.sum(
            ops.cut_eval(a, v, c, act, impl=impl) ** 2)

    gk = jax.vmap(jax.grad(loss("pallas"), argnums=(0, 1)))(a, v)
    gr = jax.vmap(jax.grad(loss("ref"), argnums=(0, 1)))(a, v)
    for x, y in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)


def test_cut_eval_grads_random_active_property():
    """Property over random active masks (hypothesis when available):
    for ANY {0,1}^P mask, kernel grads == oracle grads and inactive
    rows are hard zeros."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    p, d = 7, 384

    @settings(max_examples=20, deadline=None)
    @given(bits=st.lists(st.booleans(), min_size=p, max_size=p),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    def prop(bits, seed):
        active = jnp.asarray(bits, jnp.float32)
        a, v, c, _, w = _cut_operands(p, d, seed=seed, active=active)
        gk = jax.grad(_quad_loss("pallas", active, w),
                      argnums=(0, 1, 2))(a, v, c)
        gr = jax.grad(_quad_loss("ref", active, w),
                      argnums=(0, 1, 2))(a, v, c)
        for x, y in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-4, atol=1e-5)
        dead = np.asarray(active) == 0.0
        assert np.all(np.asarray(gk[0])[dead] == 0.0)

    prop()


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,h,hkv,hd,blk", [
    (64, 4, 2, 32, 16), (48, 4, 4, 64, 16), (128, 8, 2, 32, 32),
])
@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, h, hkv, hd, blk, window, dtype):
    b = 2
    key = jax.random.PRNGKey(s + h + window)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, hd)).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=blk, block_k=blk)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-3 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal_unaligned_raises():
    """Non-causal + non-block-aligned used to trip a bare assert; now a
    ValueError naming the offending shapes and blocks."""
    b, s, h, hd = 1, 37, 2, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, hd))
    with pytest.raises(ValueError, match=r"non-causal.*37.*block"):
        ops.flash_attention(q, q, q, causal=False,
                            block_q=16, block_k=16)
    # aligned non-causal still works
    out = ops.flash_attention(q[:, :32], q[:, :32], q[:, :32],
                              causal=False, block_q=16, block_k=16)
    assert out.shape == (b, 32, h, hd)


def test_flash_attention_unaligned_seq():
    """S not a multiple of the block: the wrapper pads causally."""
    b, s, h, hd = 1, 37, 2, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    got = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# mlstm chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("l,hd", [(8, 8), (16, 16), (32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunk_sweep(l, hd, dtype):
    b, h = 2, 3
    key = jax.random.PRNGKey(l + hd)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, l, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, h, l, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, h, l, hd)).astype(dtype)
    li = (jax.random.normal(ks[3], (b, h, l, 1)) * 0.5)
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, l, 1)) + 2.0)
    c0 = jnp.zeros((b, h, hd, hd))
    n0 = jnp.zeros((b, h, 1, hd))
    m0 = jnp.full((b, h, 1, 1), -1e9)
    got = ops.mlstm_chunk(q, k, v, li, lf, c0, n0, m0)
    want = ref.mlstm_chunk_ref(q, k, v, li, lf, c0, n0, m0)
    tol = 6e-3 if dtype == jnp.float32 else 6e-2
    for g, w, name in zip(got, want, ["y", "c", "n", "m"]):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=tol, atol=tol, err_msg=name)


def test_mlstm_sequence_carries_state():
    """Two chunks through the kernel == one pass of the jnp oracle over
    the full sequence (state carried across chunk boundary)."""
    from repro.models.xlstm import mlstm_chunk_body, init_mlstm_state
    b, h, s, hd = 1, 2, 32, 8
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    li = jax.random.normal(ks[3], (b, s, h)) * 0.5
    lf = jnp.asarray(jax.nn.log_sigmoid(
        jax.random.normal(ks[4], (b, s, h)) + 2.0))
    state = init_mlstm_state(b, h, hd)
    y_kernel, st_kernel = ops.mlstm_sequence(q, k, v, li, lf, state,
                                             chunk=16)
    # oracle: full-sequence single chunk
    y_ref, st_ref = mlstm_chunk_body(q, k, v, li, lf, state)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(st_kernel["c"]),
                               np.asarray(st_ref["c"]),
                               rtol=2e-2, atol=2e-2)


def _mlstm_seq_inputs(s, seed=3, b=1, h=2, hd=8):
    from repro.models.xlstm import init_mlstm_state
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    li = jax.random.normal(ks[3], (b, s, h)) * 0.5
    lf = jnp.asarray(jax.nn.log_sigmoid(
        jax.random.normal(ks[4], (b, s, h)) + 2.0))
    return q, k, v, li, lf, init_mlstm_state(b, h, hd)


def test_mlstm_sequence_ragged_tail():
    """S % chunk != 0 must produce ALL S outputs (the old host chunk
    loop silently dropped the ragged tail) and match the full-sequence
    oracle."""
    from repro.models.xlstm import mlstm_chunk_body
    s = 33
    q, k, v, li, lf, state = _mlstm_seq_inputs(s)
    y, st = ops.mlstm_sequence(q, k, v, li, lf, state, chunk=16)
    assert y.shape[1] == s
    y_ref, st_ref = mlstm_chunk_body(q, k, v, li, lf, state)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(st["c"]), np.asarray(st_ref["c"]),
                               rtol=2e-2, atol=2e-2)


def test_mlstm_sequence_trace_count_pinned():
    """The full chunks run as ONE lax.scan: the kernel body's trace
    count must not grow with the number of chunks (a host-loop
    regression multiplies it)."""
    def traces_for(s):
        q, k, v, li, lf, state = _mlstm_seq_inputs(s, seed=s)
        before = ops.TRACE_COUNTS["mlstm_seq_body"]
        jax.block_until_ready(
            ops.mlstm_sequence(q, k, v, li, lf, state, chunk=8)[0])
        return ops.TRACE_COUNTS["mlstm_seq_body"] - before

    # scan may trace its body a small fixed number of times, but the
    # count must be identical for 2 chunks and 6 chunks
    t2, t6 = traces_for(16), traces_for(48)
    assert t6 == t2, (t2, t6)
